"""Property tests: the socket (multi-HOST) shard pool must agree with
the unsharded reference core under random op streams — evaluations
interleaved with per-image invalidations AND host churn (kills that
condemn hosts mid-stream and re-home their images onto survivors).

Mirrors ``tests/test_serving_mp_fuzz.py`` with worker processes replaced
by shard HOSTS and a ``kill`` op added to the stream.  The host pool is
spawned once per module and shared across hypothesis examples: condemned
hosts stay condemned (the condemn-never-reuse discipline), which only
makes later interleavings harsher — parity never depends on which hosts
survive, because every host holds a full core over identical traces and
invalidations are mirrored on both sides.  The kill op is a no-op once
one host remains, so the pool always keeps serving.
"""
import os
import signal

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
pytest.importorskip("jax")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.federation.evaluation import SubsetEvaluationCore  # noqa: E402
from repro.federation.providers import default_providers  # noqa: E402
from repro.federation.traces import generate_traces  # noqa: E402
from repro.serving.socket_shards import \
    SocketShardedSubsetEvaluationCore  # noqa: E402

pytestmark = pytest.mark.slow

TR = generate_traces(default_providers(), 20, seed=9)
N = TR.n_providers
ALL_MASKS = list(range(1, 1 << N))
H = 3


@pytest.fixture(scope="module")
def pair():
    ref = SubsetEvaluationCore(TR)
    cut = SocketShardedSubsetEvaluationCore(TR, n_shards=H)
    yield ref, cut
    cut.close()


# op stream: evaluations, invalidations, and host churn
_op = st.one_of(
    st.tuples(st.just("ap"), st.integers(0, len(TR) - 1),
              st.sampled_from(ALL_MASKS)),
    st.tuples(st.just("ens"), st.integers(0, len(TR) - 1),
              st.sampled_from(ALL_MASKS)),
    st.tuples(st.just("inv"),
              st.lists(st.integers(0, len(TR) - 1), min_size=1,
                       max_size=6)),
    st.tuples(st.just("kill"), st.integers(0, H - 1)),
)


@settings(max_examples=10, deadline=None)
@given(ops=st.lists(_op, min_size=1, max_size=25))
def test_socket_shards_match_unsharded_under_churn(pair, ops):
    ref, cut = pair
    for op in ops:
        if op[0] == "kill":
            # churn: condemn a host mid-stream (kept no-op at one
            # survivor so the pool keeps serving for later examples)
            healthy = cut.healthy_hosts()
            if len(healthy) > 1:
                victim = healthy[op[1] % len(healthy)]
                os.kill(cut.host_pids()[victim], signal.SIGKILL)
                # first touch surfaces the death; eval_on requeues, so
                # correctness below never depends on when it lands
        elif op[0] == "inv":
            # mirror the drop on both sides; counts may differ only by
            # entries surviving from earlier examples on one side
            ref.invalidate_images(op[1])
            cut.invalidate_images(op[1])
        elif op[0] == "ap":
            assert cut.ap50(op[1], op[2]) == ref.ap50(op[1], op[2])
        else:
            a, b = cut.ensemble(op[1], op[2]), ref.ensemble(op[1], op[2])
            np.testing.assert_array_equal(a.boxes, b.boxes)
            np.testing.assert_array_equal(a.scores, b.scores)
            np.testing.assert_array_equal(a.labels, b.labels)
    # at least one host always survives, and routing stays total over
    # the healthy set
    assert len(cut.healthy_hosts()) >= 1
    groups = cut.partition(range(len(TR)))
    assert sorted(i for g in groups.values() for i in g) == \
        list(range(len(TR)))
    assert set(groups) <= set(cut.healthy_hosts())
