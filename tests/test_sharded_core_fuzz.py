"""Property tests: ShardedSubsetEvaluationCore must agree with the
unsharded core under random shard counts and interleaved per-image
invalidations, and its partition invariants must survive them."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
pytest.importorskip("jax")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.federation.evaluation import (  # noqa: E402
    ShardedSubsetEvaluationCore, SubsetEvaluationCore)
from repro.federation.providers import default_providers  # noqa: E402
from repro.federation.traces import generate_traces  # noqa: E402

TR = generate_traces(default_providers(), 20, seed=9)
N = TR.n_providers
ALL_MASKS = list(range(1, 1 << N))

# op stream: ("ap", img, mask) | ("ens", img, mask) | ("inv", [imgs])
#          | ("lat", img, against)
_op = st.one_of(
    st.tuples(st.just("ap"), st.integers(0, len(TR) - 1),
              st.sampled_from(ALL_MASKS)),
    st.tuples(st.just("ens"), st.integers(0, len(TR) - 1),
              st.sampled_from(ALL_MASKS)),
    st.tuples(st.just("inv"),
              st.lists(st.integers(0, len(TR) - 1), min_size=1,
                       max_size=6)),
    st.tuples(st.just("lat"), st.integers(0, len(TR) - 1),
              st.sampled_from(["gt", "pseudo"])),
)


@settings(max_examples=25, deadline=None)
@given(n_shards=st.integers(1, 6), ops=st.lists(_op, min_size=1,
                                                max_size=40))
def test_sharded_matches_unsharded_under_invalidations(n_shards, ops):
    ref = SubsetEvaluationCore(TR)
    cut = ShardedSubsetEvaluationCore(TR, n_shards=n_shards)
    for op in ops:
        if op[0] == "inv":
            dropped_ref = ref.invalidate_images(op[1])
            dropped_cut = cut.invalidate_images(op[1])
            assert dropped_ref == dropped_cut
        elif op[0] == "ap":
            assert cut.ap50(op[1], op[2]) == ref.ap50(op[1], op[2])
        elif op[0] == "lat":
            # full-lattice rows must survive interleaved invalidations:
            # a stale back-filled row resurrecting here would desync the
            # sharded and unsharded answers
            a = cut.evaluate_lattice(op[1], against=op[2])
            b = ref.evaluate_lattice(op[1], against=op[2])
            np.testing.assert_array_equal(a.masks, b.masks)
            np.testing.assert_array_equal(a.ap, b.ap)
            np.testing.assert_array_equal(a.cost, b.cost)
            np.testing.assert_array_equal(a.offsets, b.offsets)
            np.testing.assert_array_equal(a.boxes, b.boxes)
            np.testing.assert_array_equal(a.scores, b.scores)
        else:
            a, b = cut.ensemble(op[1], op[2]), ref.ensemble(op[1], op[2])
            np.testing.assert_array_equal(a.boxes, b.boxes)
            np.testing.assert_array_equal(a.scores, b.scores)
            np.testing.assert_array_equal(a.labels, b.labels)
        # partition invariants hold after every op: entries only in their
        # home shard, no duplicates, aggregate == reference cache
        shard_imgs = cut.shard_images()
        flat = [i for imgs in shard_imgs for i in imgs]
        assert len(flat) == len(set(flat))
        for sid, imgs in enumerate(shard_imgs):
            assert all(i % n_shards == sid for i in imgs)
        assert sorted(flat) == ref.cached_images()
    assert cut.cache_sizes() == ref.cache_sizes()


@settings(max_examples=15, deadline=None)
@given(n_shards=st.integers(1, 5),
       imgs=st.lists(st.integers(0, len(TR) - 1), min_size=1, max_size=12),
       drop=st.lists(st.integers(0, len(TR) - 1), min_size=1, max_size=12))
def test_invalidate_then_recompute_is_identical(n_shards, imgs, drop):
    """Invalidation must be loss-free: recomputed answers equal the
    pre-invalidation answers bit for bit."""
    core = ShardedSubsetEvaluationCore(TR, n_shards=n_shards)
    mask = (1 << N) - 1
    before = {i: core.ap50(i, mask) for i in imgs}
    core.invalidate_images(drop)
    for i in imgs:
        assert core.ap50(i, mask) == before[i]
    # a second invalidation of already-dropped images is a no-op
    core.invalidate_images(drop)
    for i in imgs:
        assert core.ap50(i, mask) == before[i]
