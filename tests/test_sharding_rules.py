"""Sharding-rule unit tests (pure PartitionSpec logic — no devices) and a
small real-mesh pjit integration test on the host device."""
import numpy as np
import pytest

pytest.importorskip("jax")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs.base import get_arch
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model


def _specs_for(aid, mode="2d", model_size=16, data_size=16):
    cfg = get_arch(aid)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    out = {}

    def walk(path, leaf):
        out[jax.tree_util.keystr(path)] = shd.param_pspec(
            path, leaf, cfg, model_size=model_size, data_size=data_size,
            mode=mode)
        return leaf
    jax.tree_util.tree_map_with_path(walk, shapes)
    return out, shapes


def test_dense_tp_rules():
    specs, shapes = _specs_for("qwen1.5-110b", mode="tp")
    assert specs["['embed']"] == P("model", None)
    assert specs["['blocks']['attn']['wq']"] == P(None, None, "model")
    # kv heads = 8 < 16 -> replicated kv projections
    assert specs["['blocks']['attn']['wk']"] == P(None, None, None)
    assert specs["['blocks']['attn']['wo']"] == P(None, "model", None)
    assert specs["['blocks']['mlp']['w_gate']"] == P(None, None, "model")
    assert specs["['blocks']['mlp']['w_down']"] == P(None, "model", None)


def test_dense_2d_adds_fsdp_axis():
    specs, _ = _specs_for("qwen1.5-110b", mode="2d")
    assert specs["['blocks']['attn']['wq']"] == P(None, "data", "model")
    assert specs["['blocks']['mlp']['w_down']"] == P(None, "model", "data")


def test_moe_expert_parallel():
    specs, _ = _specs_for("olmoe-1b-7b", mode="2d")
    # (L, E, d, dff): experts (64) over model axis
    assert specs["['blocks']['moe']['w_gate']"] == \
        P(None, "model", "data", None)
    assert specs["['blocks']['moe']['router']"] == P(None, "data", None)


def test_deepseek_mla_rules():
    specs, _ = _specs_for("deepseek-v2-236b", mode="2d")
    # wq_a deliberately replicated (EXPERIMENTS.md §Perf iteration 2)
    assert specs["['blocks']['attn']['wq_a']"][-1] is None
    assert specs["['blocks']['attn']['wk_b']"][-1] == "model"   # 128 heads
    assert specs["['blocks']['moe']['w_gate']"] == \
        P(None, "model", "data", None)   # 160 experts / 16


def test_mamba_head_parallel():
    specs, _ = _specs_for("mamba2-370m", mode="tp")
    assert specs["['blocks']['mamba']['in_x']"] == P(None, None, "model")
    assert specs["['blocks']['mamba']['in_z']"] == P(None, None, "model")
    assert specs["['blocks']['mamba']['in_bc']"] == P(None, None, None)
    assert specs["['blocks']['mamba']['out_proj']"] == P(None, "model", None)
    assert specs["['blocks']['mamba']['conv_x']"] == P(None, "model", None)


def test_vlm_nested_stack_rules():
    specs, _ = _specs_for("llama-3.2-vision-11b", mode="tp")
    # selfs carry TWO leading stack dims (super, per-1)
    assert specs["['blocks']['selfs']['attn']['wq']"] == \
        P(None, None, None, "model")
    assert specs["['blocks']['cross']['attn']['wq']"] == \
        P(None, None, "model")


def test_cache_specs_decode():
    cfg = get_arch("command-r-plus-104b")
    model = build_model(cfg)
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(None, 128, 32768, None))
    spec_k = shd.cache_pspec(
        (jax.tree_util.DictKey("k"),), cache_shapes["k"], cfg,
        model_size=16, data_size=16, global_batch=128)
    # kv=8 not divisible by 16 -> sequence-sharded cache
    assert spec_k == P(None, "data", "model", None, None)
    cfg2 = get_arch("qwen1.5-0.5b")                  # kv=16 -> head-sharded
    model2 = build_model(cfg2)
    cs2 = jax.eval_shape(lambda: model2.init_cache(None, 128, 32768, None))
    spec_k2 = shd.cache_pspec(
        (jax.tree_util.DictKey("k"),), cs2["k"], cfg2,
        model_size=16, data_size=16, global_batch=128)
    assert spec_k2 == P(None, "data", None, "model", None)


def test_batch_pspec_fallbacks():
    mesh = make_host_mesh()
    assert shd.batch_pspec(mesh, 16) == P(("data",))
    # batch=1 not divisible -> replicated
    if mesh.shape["data"] > 1:
        assert shd.batch_pspec(mesh, 1) == P(None)


def test_pjit_forward_on_host_mesh():
    """End-to-end pjit with the rule-derived shardings on the real device."""
    cfg = get_arch("qwen1.5-0.5b").reduced()
    model = build_model(cfg, dtype=jnp.float32)
    mesh = make_host_mesh(model=1, data=1)
    params = model.init(jax.random.PRNGKey(0))
    shardings = shd.params_shardings(
        mesh, jax.eval_shape(model.init, jax.random.PRNGKey(0)), cfg, "tp")
    params = jax.device_put(params, shardings)
    tokens = jnp.zeros((2, 16), jnp.int32)
    fn = jax.jit(lambda p, t: model.forward(p, {"tokens": t})[0])
    logits = fn(params, tokens)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
