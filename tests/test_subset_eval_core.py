"""Parity tests: the batched/cached subset-evaluation core must reproduce
the per-image seed path (fresh ensemble_detections + image_ap50 per
(image, action) pair) bit for bit — metrics {ap50, map, cost, counts} and
the raw detection arrays."""
import itertools

import numpy as np
import pytest

from repro.core.loops import (agent_policy, ensembleN_policy,
                              enumeration_actions, evaluate_policy,
                              upper_bound)
from repro.ensemble.boxes import Detections
from repro.ensemble.metrics import ap50, coco_map, image_ap50
from repro.ensemble.pipeline import (ensemble_detections,
                                     ensemble_detections_batch)
from repro.federation.env import ArmolEnv
from repro.federation.evaluation import (SubsetEvaluationCore,
                                         action_to_mask, mask_to_action,
                                         popcount_masks)
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces

TR = generate_traces(default_providers(), 60, seed=11)
N = TR.n_providers
ACTIONS = enumeration_actions(N)


def det_policy(env):
    """Deterministic state-dependent policy (no RNG, so the batched and
    per-image call orders see identical actions)."""
    def f(s):
        s = np.atleast_2d(s)
        a = (s[:, :N] > np.median(s[:, :N], axis=1, keepdims=True))
        a = a.astype(np.float32)
        a[a.sum(axis=1) == 0, 0] = 1.0
        out = a if len(a) > 1 else a[0]
        return out
    f.select_batch = f
    return f


# ---------------------------------------------------------------------------
# per-pair parity
# ---------------------------------------------------------------------------

def test_core_matches_per_image_path_exactly():
    env = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)
    for img in range(0, 20):
        gt = TR.gts[img]
        for a in ACTIONS:
            sel = [TR.dets[img][i] for i in range(N) if a[i] > 0.5]
            d_ref = ensemble_detections(sel)
            v_ref = image_ap50(d_ref, gt)
            c_ref = float(np.sum(env.costs * (a > 0.5)))
            r, v, c = env.evaluate_action(img, a)
            assert c == c_ref
            assert v == v_ref
            assert r == (-1.0 if len(d_ref) == 0 else v_ref)
            d = env.ensemble_for(img, a)
            np.testing.assert_array_equal(d.boxes, d_ref.boxes)
            np.testing.assert_array_equal(d.scores, d_ref.scores)
            np.testing.assert_array_equal(d.labels, d_ref.labels)
            np.testing.assert_array_equal(d.providers, d_ref.providers)


def test_core_memoizes():
    core = SubsetEvaluationCore(TR)
    a = np.ones(N, np.float32)
    d1 = core.ensemble(3, core.mask_of(a))
    misses = core.stats["ens_misses"]
    d2 = core.ensemble(3, core.mask_of(a))
    assert d1 is d2
    assert core.stats["ens_misses"] == misses
    assert core.stats["ens_hits"] >= 1


def test_pseudo_gt_matches_full_ensemble():
    env = ArmolEnv(TR, mode="nogt", beta=0.0, seed=0)
    img = 5
    ref = ensemble_detections(TR.dets[img])
    got = env.pseudo_gt(img)
    np.testing.assert_array_equal(got.boxes, ref.boxes)
    np.testing.assert_array_equal(got.scores, ref.scores)


def test_nogt_reward_uses_pseudo_reference():
    env = ArmolEnv(TR, mode="nogt", beta=0.0, seed=0)
    img = int(env.train_idx[0])
    a = np.ones(N, np.float32)
    ens = ensemble_detections(TR.dets[img])
    v_ref = image_ap50(ens, env.pseudo_gt(img))
    _, v, _ = env.evaluate_action(img, a)
    assert v == v_ref


# ---------------------------------------------------------------------------
# evaluate_policy / upper_bound parity vs seed-style loops
# ---------------------------------------------------------------------------

def seed_evaluate_policy(select_fn, env):
    """The seed's evaluate_policy, verbatim semantics."""
    dts, gts = {}, {}
    counts = np.zeros(env.n_providers, np.int64)
    total_cost = 0.0
    for img in env.test_idx:
        a = np.asarray(select_fn(env.features[img]), np.float32)
        counts += (a > 0.5).astype(np.int64)
        total_cost += float(np.sum(env.costs * (a > 0.5)))
        sel = [env.traces.dets[int(img)][i]
               for i in range(env.n_providers) if a[i] > 0.5]
        dts[int(img)] = (ensemble_detections(sel) if sel
                         else Detections.empty())
        gts[int(img)] = env.traces.gts[int(img)]
    n = max(len(env.test_idx), 1)
    return {"ap50": 100.0 * ap50(dts, gts),
            "map": 100.0 * coco_map(dts, gts), "cost": total_cost / n,
            "counts": counts.tolist(), "n_images": n}


def seed_upper_bound(env):
    """The seed's Algo.-2 brute force, verbatim semantics."""
    n = env.n_providers
    actions = []
    for a in itertools.product([0, 1], repeat=n):
        if any(a):
            actions.append(np.asarray(a, np.float32))
    actions.sort(key=lambda a: (a.sum(),))
    dts, gts = {}, {}
    counts = np.zeros(n, np.int64)
    total_cost = 0.0
    for img in env.test_idx:
        best_v, best_a, best_d = -1.0, None, None
        gt = env.traces.gts[int(img)]
        for a in actions:
            sel = [env.traces.dets[int(img)][i] for i in range(n)
                   if a[i] > 0.5]
            d = ensemble_detections(sel) if sel else Detections.empty()
            v = image_ap50(d, gt)
            if v > best_v:
                best_v, best_a, best_d = v, a, d
        counts += (best_a > 0.5).astype(np.int64)
        total_cost += float(np.sum(env.costs * (best_a > 0.5)))
        dts[int(img)] = best_d
        gts[int(img)] = gt
    m = max(len(env.test_idx), 1)
    return {"ap50": 100.0 * ap50(dts, gts),
            "map": 100.0 * coco_map(dts, gts), "cost": total_cost / m,
            "counts": counts.tolist(), "n_images": m}


def test_evaluate_policy_bitwise_parity():
    env = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)
    pol = det_policy(env)
    got = evaluate_policy(pol, env)
    ref = seed_evaluate_policy(pol, env)
    assert got == ref


def test_evaluate_policy_parity_unbatched_policy():
    env = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)
    got = evaluate_policy(ensembleN_policy(env), env)
    ref = seed_evaluate_policy(ensembleN_policy(env), env)
    assert got == ref


def test_upper_bound_bitwise_parity():
    env = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)
    assert upper_bound(env) == seed_upper_bound(env)


# ---------------------------------------------------------------------------
# batched env APIs
# ---------------------------------------------------------------------------

def test_evaluate_actions_matches_scalar():
    env = ArmolEnv(TR, mode="gt", beta=-0.1, seed=0)
    rng = np.random.default_rng(0)
    imgs = rng.integers(0, len(TR), 16)
    acts = np.stack([ACTIONS[i % len(ACTIONS)] for i in range(16)])
    out = env.evaluate_actions(imgs, acts)
    for t in range(16):
        r, v, c = env.evaluate_action(int(imgs[t]), acts[t])
        assert out["reward"][t] == r
        assert out["ap50"][t] == v
        assert out["cost"][t] == c


def test_step_batch_matches_step():
    env_a = ArmolEnv(TR, mode="gt", beta=0.0, seed=4)
    env_b = ArmolEnv(TR, mode="gt", beta=0.0, seed=4)
    env_a.reset(split="train", shuffle=False)
    env_b.reset(split="train", shuffle=False)
    acts = np.stack([ACTIONS[i % len(ACTIONS)] for i in range(10)])
    nxt, rew, done, infos = env_a.step_batch(acts)
    for t in range(10):
        n_ref, r_ref, d_ref, i_ref = env_b.step(acts[t])
        assert rew[t] == r_ref and done[t] == d_ref
        assert infos["image"][t] == i_ref["image"]
        np.testing.assert_array_equal(nxt[t], n_ref)
    assert env_a._t == env_b._t


def test_step_batch_clips_at_episode_end():
    env = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)
    env.reset(split="test", shuffle=False)
    B = len(env.test_idx)
    acts = np.ones((B + 7, N), np.float32)
    _, rew, done, _ = env.step_batch(acts)
    assert len(rew) == B
    assert done[-1] and not done[:-1].any()


# ---------------------------------------------------------------------------
# batch ensemble pipeline + mask helpers
# ---------------------------------------------------------------------------

def test_ensemble_detections_batch_matches_single():
    per_image = [TR.dets[i] for i in range(8)] + [[]]
    batch = ensemble_detections_batch(per_image)
    for sel, got in zip(per_image, batch):
        ref = (ensemble_detections(sel) if sel else Detections.empty())
        np.testing.assert_array_equal(got.boxes, ref.boxes)
        np.testing.assert_array_equal(got.scores, ref.scores)
        np.testing.assert_array_equal(got.labels, ref.labels)


def test_mask_roundtrip_and_popcount_order():
    for a in ACTIONS:
        m = action_to_mask(a)
        np.testing.assert_array_equal(mask_to_action(m, N), a)
    masks = popcount_masks(N)
    assert masks == [action_to_mask(a) for a in ACTIONS]
    pops = [bin(m).count("1") for m in masks]
    assert pops == sorted(pops)


def test_agent_policy_batched_matches_single():
    class StubAgent:
        def select_action(self, s, *, deterministic=False):
            s = np.asarray(s)
            a = (s[..., :N] > 0).astype(np.float32)
            flat = a.reshape(-1, N)
            flat[flat.sum(axis=1) == 0, 0] = 1.0
            return flat.reshape(a.shape), None

    env = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)
    pol = agent_policy(StubAgent())
    batch = pol.select_batch(env.features[env.test_idx])
    single = np.stack([pol(env.features[i]) for i in env.test_idx])
    np.testing.assert_array_equal(batch, single)


def test_pickled_core_arrives_cold_and_answers_identically():
    """The pickle contract of the serving plane: a core crossing a
    process boundary ships WITHOUT its memo caches (payload stays small)
    and, rebuilt on the far side, answers bit-for-bit identically."""
    import pickle

    core = SubsetEvaluationCore(TR)
    full = (1 << N) - 1
    warm = {(i, m): core.ap50(i, m) for i in (0, 3, 7) for m in (1, 5, full)}
    blob = pickle.dumps(core)
    clone = pickle.loads(blob)
    assert clone.cache_sizes() == {"tables": 0, "ensembles": 0,
                                   "ap_entries": 0,
                                   "lattices": 0}           # arrives cold
    assert all(v == 0 for v in clone.stats.values())
    for (i, m), want in warm.items():
        assert clone.ap50(i, m) == want
        a, b = clone.ensemble(i, m), core.ensemble(i, m)
        np.testing.assert_array_equal(a.boxes, b.boxes)
        np.testing.assert_array_equal(a.scores, b.scores)
    # stripping the caches is what keeps the payload shippable: the blob
    # must not grow with cache temperature
    assert len(blob) <= len(pickle.dumps(SubsetEvaluationCore(TR))) * 1.1
