"""Optimizer, schedules, data pipeline, checkpointing."""
import os

import numpy as np
import pytest

pytest.importorskip("jax")
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint.store import load_pytree, save_pytree
from repro.configs.base import ShapeConfig, get_arch
from repro.data.pipeline import batch_for, synthetic_lm_batches
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedules import cosine_schedule, linear_warmup


def test_adamw_minimises_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(params, grads, state, lr=0.05)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.asarray([1.0])}
    state = adamw_init(params)
    p2, _ = adamw_update(params, {"w": jnp.asarray([0.0])}, state, lr=0.1,
                         weight_decay=0.5)
    assert float(p2["w"][0]) < 1.0


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert norm == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                 rel=1e-5)


@given(st.integers(0, 5000))
@settings(max_examples=50, deadline=None)
def test_cosine_schedule_bounds(step):
    lr = float(cosine_schedule(jnp.asarray(step), peak_lr=1e-3,
                               warmup_steps=100, total_steps=5000))
    assert 0.0 < lr <= 1e-3 + 1e-9


def test_warmup_monotone():
    vals = [float(linear_warmup(jnp.asarray(s), peak_lr=1.0,
                                warmup_steps=10)) for s in range(12)]
    assert vals[:10] == sorted(vals[:10])
    assert vals[10] == pytest.approx(1.0)


def test_data_pipeline_deterministic_and_learnable():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    g1 = synthetic_lm_batches(cfg, 4, 32, seed=5)
    g2 = synthetic_lm_batches(cfg, 4, 32, seed=5)
    b1, b2 = next(g1), next(g2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # bigram structure: labels mostly follow the transition table
    assert b1["labels"].shape == (4, 32)


def test_batch_for_modalities():
    vlm = get_arch("llama-3.2-vision-11b").reduced()
    b = batch_for(vlm, ShapeConfig("t", 16, 2, "train"))
    assert b["image_embeds"].shape == (2, vlm.num_image_tokens, vlm.d_vision)
    audio = get_arch("seamless-m4t-medium").reduced()
    b = batch_for(audio, ShapeConfig("t", 16, 2, "train"))
    assert b["audio_frames"].shape == (2, audio.num_audio_frames,
                                       audio.d_model)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.asarray([1.0, 2.0], jnp.bfloat16),
            "b": {"c": jnp.arange(6, dtype=jnp.int32).reshape(2, 3)},
            "d": jnp.asarray(3.5, jnp.float32)}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    out = load_pytree(path, like)
    assert out["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                  [1.0, 2.0])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, {"a": jnp.zeros((2,))})
    with pytest.raises(AssertionError):
        load_pytree(path, {"a": jnp.zeros((3,))})
