"""End-to-end behaviour tests: the full Armol loop (traces -> word grouping
-> RL selection -> ensemble -> reward), short SAC training improving over
its own start, and the deployable federation service."""
import numpy as np
import pytest

from repro.core.loops import evaluate_policy, run_off_policy
from repro.core.sac import SAC, SACConfig
from repro.federation.env import ArmolEnv
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces
from repro.serving.federation_service import FederationService

TR = generate_traces(default_providers(), 150, seed=7)


def test_full_loop_one_episode():
    env = ArmolEnv(TR, mode="gt", beta=0.0, seed=0)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, seed=0))
    s = env.reset(split="train")
    rewards = []
    for _ in range(20):
        a, proto = agent.select_action(s)
        assert set(np.unique(a)).issubset({0.0, 1.0}) and a.sum() >= 1
        s, r, done, info = env.step(a)
        rewards.append(r)
        assert -1.0 <= r <= 1.0
        assert info["cost"] >= 1.0
    assert np.isfinite(rewards).all()


def test_sac_training_improves_reward():
    env = ArmolEnv(TR, mode="gt", beta=0.0, seed=1)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, seed=1))
    before = evaluate_policy(
        lambda s: agent.select_action(s, deterministic=True)[0], env)
    hist = run_off_policy(agent, env, epochs=2, steps_per_epoch=120,
                          batch_size=64, start_steps=60, update_after=60,
                          update_every=20, update_iters=20, log=None)
    after = hist[-1]
    # learned policy must not regress vs the untrained one (cost-free env)
    assert after["ap50"] >= before["ap50"] - 1.0


def test_federation_service_accounting():
    env = ArmolEnv(TR, mode="gt", beta=0.0, seed=2)
    agent = SAC(SACConfig(state_dim=env.state_dim,
                          n_providers=env.n_providers, seed=2))
    svc = FederationService(env, agent)
    res = svc.handle(int(env.test_idx[0]))
    n_sel = int(res.action.sum())
    assert n_sel >= 1
    assert res.cost_milli_usd == pytest.approx(float(n_sel))
    # latency: sequential transmission + parallel inference (Sec. II-B)
    assert res.latency_ms >= 20.0 * n_sel
    many = svc.handle_many(env.test_idx[:5])
    assert len(many) == 5


def test_wordgroup_to_reward_path_is_consistent():
    """The pseudo ground truth (w/o-gt mode) must score ~1.0 against
    itself — validating the grouping -> ensemble -> metric path."""
    env = ArmolEnv(TR, mode="nogt", beta=0.0, seed=3)
    img = int(env.train_idx[1])
    r, v, c = env.evaluate_action(img, np.ones(3, np.float32))
    if r != -1.0:
        assert v > 0.9
