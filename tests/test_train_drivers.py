"""Batched-vs-sequential training-driver parity suite.

The multi-lane drivers (``run_off_policy`` / ``run_ppo``) must reproduce
the frozen sequential references bit-for-bit at ``lanes=1`` (same
transition stream, same evaluation history), reach at least the same eval
AP50 at ``lanes>1``, and their fused ``lax.scan`` update blocks must match
eager per-step updates on identical pre-sampled batches.
"""
import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core.loops import (run_off_policy, run_offpolicy_sequential,
                              run_ppo, run_ppo_sequential)
from repro.core.ppo import PPO, PPOConfig
from repro.core.replay_buffer import ReplayBuffer
from repro.core.sac import SAC, SACConfig
from repro.core.td3 import TD3, TD3Config
from repro.federation.env import ArmolEnv
from repro.federation.providers import default_providers
from repro.federation.traces import generate_traces

TR = generate_traces(default_providers(), 60, seed=0)
N = TR.n_providers

OFFPOLICY_KW = dict(epochs=2, steps_per_epoch=30, batch_size=32,
                    start_steps=10, update_after=10, update_every=10,
                    update_iters=5, log=None, seed=5)


def _env(seed=3):
    return ArmolEnv(TR, mode="gt", beta=-0.03, seed=seed)


def _agent(algo, seed=0):
    env = _env()
    if algo == "sac":
        return SAC(SACConfig(state_dim=env.state_dim, n_providers=N,
                             alpha=0.02, seed=seed))
    if algo == "td3":
        return TD3(TD3Config(state_dim=env.state_dim, n_providers=N,
                             seed=seed))
    return PPO(PPOConfig(state_dim=env.state_dim, n_providers=N,
                         minibatch=32, seed=seed))


def _strip_wall(history):
    return [{k: v for k, v in h.items() if k != "wall_s"} for h in history]


def _buf(env, seed=5):
    return ReplayBuffer(1000, env.state_dim, N, seed=seed)


# ---------------------------------------------------------------------------
# L=1 bitwise parity: transition stream + evaluation history
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["sac", "td3"])
def test_offpolicy_lane1_bitwise_parity(algo):
    env_a, env_b = _env(), _env()
    buf_a, buf_b = _buf(env_a), _buf(env_b)
    h_seq = run_offpolicy_sequential(_agent(algo), env_a, buffer=buf_a,
                                     **OFFPOLICY_KW)
    h_bat = run_off_policy(_agent(algo), env_b, lanes=1, buffer=buf_b,
                           **OFFPOLICY_KW)
    # identical transition stream, bit for bit
    for field in ("state", "action", "reward", "next_state", "done"):
        np.testing.assert_array_equal(getattr(buf_a, field),
                                      getattr(buf_b, field), err_msg=field)
    assert (buf_a.ptr, buf_a.size) == (buf_b.ptr, buf_b.size)
    # identical evaluation history (wall time excluded)
    assert _strip_wall(h_seq) == _strip_wall(h_bat)


def test_ppo_lane1_bitwise_parity():
    env_a, env_b = _env(), _env()
    h_seq = run_ppo_sequential(_agent("ppo"), env_a, epochs=2,
                               steps_per_epoch=30, log=None)
    h_bat = run_ppo(_agent("ppo"), env_b, lanes=1, epochs=2,
                    steps_per_epoch=30, log=None)
    assert _strip_wall(h_seq) == _strip_wall(h_bat)


# ---------------------------------------------------------------------------
# L>1: the multi-lane driver trains at least as well on the tiny trace
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_offpolicy_multilane_reaches_sequential_ap50():
    kw = dict(OFFPOLICY_KW, epochs=3, steps_per_epoch=48)
    h_seq = run_offpolicy_sequential(_agent("sac"), _env(), **kw)
    h_bat = run_off_policy(_agent("sac"), _env(), lanes=4, **kw)
    assert h_bat[-1]["steps"] >= h_seq[-1]["steps"]
    best_seq = max(h["ap50"] for h in h_seq)
    best_bat = max(h["ap50"] for h in h_bat)
    assert best_bat >= best_seq - 1e-9, (best_bat, best_seq)


@pytest.mark.slow
def test_ppo_multilane_reaches_sequential_ap50():
    h_seq = run_ppo_sequential(_agent("ppo"), _env(), epochs=2,
                               steps_per_epoch=64, log=None)
    h_bat = run_ppo(_agent("ppo"), _env(), lanes=4, epochs=2,
                    steps_per_epoch=64, log=None)
    best_seq = max(h["ap50"] for h in h_seq)
    best_bat = max(h["ap50"] for h in h_bat)
    assert best_bat >= best_seq - 1e-9, (best_bat, best_seq)


# ---------------------------------------------------------------------------
# Seed / determinism: lane rng streams must be independent and reproducible
# ---------------------------------------------------------------------------

def test_batched_driver_seed_determinism():
    kw = dict(OFFPOLICY_KW, epochs=1, steps_per_epoch=20)
    runs = {}
    for tag, seed in (("a", 5), ("b", 5), ("c", 6)):
        env = _env()
        buf = _buf(env, seed=seed)
        runs[tag] = (run_off_policy(_agent("sac"), env, lanes=4, buffer=buf,
                                    **dict(kw, seed=seed)), buf)
    h_a, buf_a = runs["a"]
    h_b, buf_b = runs["b"]
    h_c, buf_c = runs["c"]
    assert _strip_wall(h_a) == _strip_wall(h_b)
    for field in ("state", "action", "reward", "next_state", "done"):
        np.testing.assert_array_equal(getattr(buf_a, field),
                                      getattr(buf_b, field))
    # a different driver seed must change the exploration stream
    assert not np.array_equal(buf_a.action, buf_c.action)


def test_lanes_do_not_share_exploration_rng():
    """During pure exploration every tick draws per-lane actions from one
    generator stream — lanes must not all mirror each other."""
    env = _env()
    buf = _buf(env)
    run_off_policy(_agent("sac"), env, lanes=4, buffer=buf,
                   **dict(OFFPOLICY_KW, epochs=1, steps_per_epoch=16,
                          start_steps=16, update_after=1000))
    acts = buf.action[:16].reshape(4, 4, N)   # (ticks, lanes, N)
    identical_ticks = sum(
        all(np.array_equal(tick[0], tick[lane]) for lane in range(1, 4))
        for tick in acts)
    assert identical_ticks < len(acts)


# ---------------------------------------------------------------------------
# Fused lax.scan update blocks == eager per-step updates
# ---------------------------------------------------------------------------

def _stacked_batches(rng, iters, batch, state_dim, n):
    return {"s": rng.standard_normal((iters, batch, state_dim)
                                     ).astype(np.float32),
            "a": (rng.random((iters, batch, n)) > 0.5).astype(np.float32),
            "r": rng.standard_normal((iters, batch)).astype(np.float32),
            "s2": rng.standard_normal((iters, batch, state_dim)
                                      ).astype(np.float32),
            "d": (rng.random((iters, batch)) > 0.8).astype(np.float32)}


@pytest.mark.parametrize("algo", ["sac", "td3"])
def test_update_block_matches_eager_updates(algo):
    import jax
    eager, fused = _agent(algo), _agent(algo)
    state_dim = eager.cfg.state_dim
    batches = _stacked_batches(np.random.default_rng(0), 6, 32, state_dim, N)
    for k in range(6):
        eager.update({key: v[k] for key, v in batches.items()})
    fused.update_block(batches)
    for le, lf in zip(jax.tree.leaves(eager.state),
                      jax.tree.leaves(fused.state)):
        np.testing.assert_allclose(np.asarray(le), np.asarray(lf),
                                   rtol=0, atol=1e-6)


def test_ppo_update_minibatches_matches_eager():
    import jax
    eager, fused = _agent("ppo"), _agent("ppo")
    rng = np.random.default_rng(1)
    K, mb = 5, 32
    state_dim = eager.cfg.state_dim
    mbs = {"s": rng.standard_normal((K, mb, state_dim)).astype(np.float32),
           "proto": rng.random((K, mb, N)).astype(np.float32) * 0.9 + 0.05,
           "logp": rng.standard_normal((K, mb)).astype(np.float32),
           "adv": rng.standard_normal((K, mb)).astype(np.float32),
           "ret": rng.standard_normal((K, mb)).astype(np.float32),
           "w": np.ones((K, mb), np.float32)}
    for k in range(K):
        eager.update_minibatch({key: v[k] for key, v in mbs.items()})
    fused.update_minibatches(mbs)
    for le, lf in zip(jax.tree.leaves(eager.state),
                      jax.tree.leaves(fused.state)):
        np.testing.assert_allclose(np.asarray(le), np.asarray(lf),
                                   rtol=0, atol=1e-6)


def test_ppo_padded_minibatch_ignores_masked_rows():
    """A weight-0 padded row must not change the update: duplicate the
    batch with garbage in the padded slots and compare params."""
    import jax
    a1, a2 = _agent("ppo"), _agent("ppo")
    rng = np.random.default_rng(2)
    mb, pad = 24, 8
    state_dim = a1.cfg.state_dim
    base = {"s": rng.standard_normal((mb + pad, state_dim)
                                     ).astype(np.float32),
            "proto": rng.random((mb + pad, N)).astype(np.float32) * 0.9
            + 0.05,
            "logp": rng.standard_normal(mb + pad).astype(np.float32),
            "adv": rng.standard_normal(mb + pad).astype(np.float32),
            "ret": rng.standard_normal(mb + pad).astype(np.float32)}
    w = np.ones(mb + pad, np.float32)
    w[mb:] = 0.0
    garbage = {k: v.copy() for k, v in base.items()}
    for k in ("s", "logp", "adv", "ret"):
        garbage[k][mb:] = 1000.0 * (1 + np.arange(pad)
                                    ).reshape([-1] + [1] * (
                                        garbage[k].ndim - 1))
    a1.update_minibatch({**base, "w": w})
    a2.update_minibatch({**garbage, "w": w})
    for l1, l2 in zip(jax.tree.leaves(a1.state), jax.tree.leaves(a2.state)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Non-property add_batch/sample_block checks (the hypothesis fuzz versions
# live in tests/test_replay_buffer_batch.py behind importorskip)
# ---------------------------------------------------------------------------

def test_add_batch_wraparound_and_overflow():
    rng = np.random.default_rng(0)
    scalar = ReplayBuffer(8, 3, 2)
    batched = ReplayBuffer(8, 3, 2)
    for B in (5, 6, 20, 0, 3):   # straddles wrap; one batch > capacity
        s = rng.standard_normal((B, 3)).astype(np.float32)
        a = rng.standard_normal((B, 2)).astype(np.float32)
        r = rng.standard_normal(B).astype(np.float32)
        s2 = rng.standard_normal((B, 3)).astype(np.float32)
        d = (rng.random(B) > 0.5).astype(np.float32)
        for i in range(B):
            scalar.add(s[i], a[i], r[i], s2[i], d[i])
        batched.add_batch(s, a, r, s2, d)
        assert (scalar.ptr, scalar.size) == (batched.ptr, batched.size)
        for field in ("state", "action", "reward", "next_state", "done"):
            np.testing.assert_array_equal(getattr(scalar, field),
                                          getattr(batched, field))


# ---------------------------------------------------------------------------
# device-resident path: same frozen references, device buffer in the loop
# ---------------------------------------------------------------------------

def _dev_buf(env, seed=5):
    from repro.core.device_replay import DeviceReplayBuffer
    return DeviceReplayBuffer(1000, env.state_dim, N, seed=seed,
                              index_mode="host",
                              feature_table=env.device_features())


@pytest.mark.parametrize("algo", ["sac", "td3"])
def test_offpolicy_lane1_device_bitwise_parity(algo):
    """L=1 with a DeviceReplayBuffer (host index mode + on-device
    feature assembly) reproduces the frozen sequential reference
    bit-for-bit: gathers are pure selection, so routing the replay
    storage and state assembly through the device changes nothing."""
    env_a, env_b = _env(), _env()
    buf_a, buf_b = _buf(env_a), _dev_buf(env_b)
    h_seq = run_offpolicy_sequential(_agent(algo), env_a, buffer=buf_a,
                                     **OFFPOLICY_KW)
    h_dev = run_off_policy(_agent(algo), env_b, lanes=1, buffer=buf_b,
                           **OFFPOLICY_KW)
    for field in ("state", "action", "reward", "next_state", "done"):
        np.testing.assert_array_equal(getattr(buf_a, field),
                                      getattr(buf_b, field), err_msg=field)
    assert (buf_a.ptr, buf_a.size) == (buf_b.ptr, buf_b.size)
    assert _strip_wall(h_seq) == _strip_wall(h_dev)


@pytest.mark.slow
def test_offpolicy_multilane_device_matches_host_buffer():
    """L=8: swapping the numpy buffer for the device buffer changes
    neither the transition stream nor the evaluation history."""
    env_a, env_b = _env(), _env()
    buf_a, buf_b = _buf(env_a), _dev_buf(env_b)
    h_host = run_off_policy(_agent("sac"), env_a, lanes=8, buffer=buf_a,
                            **OFFPOLICY_KW)
    h_dev = run_off_policy(_agent("sac"), env_b, lanes=8, buffer=buf_b,
                           **OFFPOLICY_KW)
    for field in ("state", "action", "reward", "next_state", "done"):
        np.testing.assert_array_equal(getattr(buf_a, field),
                                      getattr(buf_b, field), err_msg=field)
    assert _strip_wall(h_host) == _strip_wall(h_dev)


def test_ppo_device_gather_matches_host_gather():
    """``update_from_rollout`` gathers the (K, mb, ...) minibatch stack
    on device; it must be bitwise the old host-side fancy-indexing."""
    import jax
    dev, host = _agent("ppo"), _agent("ppo")
    rng = np.random.default_rng(2)
    T = 100
    state_dim = dev.cfg.state_dim
    rollout = {
        "s": rng.standard_normal((T, state_dim)).astype(np.float32),
        "proto": (rng.random((T, N)) * 0.9 + 0.05).astype(np.float32),
        "logp": rng.standard_normal(T).astype(np.float32),
        "adv": rng.standard_normal(T).astype(np.float32),
        "ret": rng.standard_normal(T).astype(np.float32)}
    dev.update_from_rollout(dict(rollout))
    # the old host path: same plan (same agent rng state), numpy gather
    idx, w = host._minibatch_plan(T)
    mbs = {k: np.asarray(v)[idx] for k, v in rollout.items()}
    mbs["w"] = w
    host.update_minibatches(mbs)
    for ld, lh in zip(jax.tree.leaves(dev.state),
                      jax.tree.leaves(host.state)):
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lh))
