"""Word-grouping (paper Sec. IV-C) tests."""
from repro.federation.vocab import COCO_TEMPLATE, WordGrouper


def test_template_has_80_categories():
    assert len(COCO_TEMPLATE) == 80
    assert len(set(COCO_TEMPLATE)) == 80


def test_identity_mapping():
    g = WordGrouper()
    for i, cat in enumerate(COCO_TEMPLATE):
        assert g.to_group(cat) == i


def test_paper_example_motorbike_motorcycle():
    g = WordGrouper()
    assert g.to_group("motorbike") == g.to_group("motorcycle")


def test_synonyms_resolve():
    g = WordGrouper()
    assert g.to_group("sofa") == COCO_TEMPLATE.index("couch")
    assert g.to_group("television") == COCO_TEMPLATE.index("tv")
    assert g.to_group("mobile phone") == COCO_TEMPLATE.index("cell phone")
    assert g.to_group("aeroplane") == COCO_TEMPLATE.index("airplane")


def test_normalisation():
    g = WordGrouper()
    assert g.to_group("  Motor-Bike ") == COCO_TEMPLATE.index("motorcycle")
    assert g.to_group("TV_Monitor") == COCO_TEMPLATE.index("tv")


def test_irrelevant_words_discarded():
    g = WordGrouper()
    for w in ("shadow", "texture", "quantum", "blur"):
        assert g.to_group(w) == -1


def test_manual_additions():
    g = WordGrouper(manual_additions={"hydroplane": "airplane"})
    assert g.to_group("hydroplane") == COCO_TEMPLATE.index("airplane")


def test_group_all():
    g = WordGrouper()
    out = g.group_all(["person", "human", "blur"])
    assert out[0] == out[1] == 0 and out[2] == -1
