#!/usr/bin/env python
"""Benchmark regression gate.

Runs ``benchmarks/run.py <name>`` for each requested benchmark with
``REPRO_RESULTS_DIR`` pointed at a scratch directory (the committed
baselines in ``benchmarks/results/`` are never overwritten), then
compares the fresh numbers against the committed ones and fails on a
warm-path regression larger than the tolerance.

Gated metrics are *ratios* (cached-vs-seed speedups, async-vs-sequential
serving speedups) wherever possible: ratios compare two code paths
measured on the same machine in the same run, so they cancel absolute
machine speed and survive CI-runner heterogeneity.

Usage:
    PYTHONPATH=src python tools/check_bench.py            # default set
    PYTHONPATH=src python tools/check_bench.py serving train_driver

Environment:
    REPRO_BENCH_TOLERANCE   allowed fractional regression before failing
                            (default 0.30).  Noisy/shared runners should
                            raise it, e.g. ``REPRO_BENCH_TOLERANCE=0.6``;
                            set it >= 1 to reduce the gate to a smoke run.
    REPRO_BENCH_RETRIES     extra fresh runs when a gate fails (default
                            1); the best per-metric value across attempts
                            is compared, absorbing transient load spikes
                            on shared machines.
    REPRO_BENCH_IMAGES etc. forwarded to benchmarks/run.py (each bench
                            defaults to its committed baseline's problem
                            size, see BENCH_ENV).
    REPRO_BENCH_SCRATCH     directory for the fresh-run JSONs (default: a
                            throwaway tempdir).  CI points this at a
                            stable path and uploads it as an artifact.

Gated benchmarks include the serving plane: ``serving_mp`` checks the
process-shard backend's capacity ratio over the thread backend at equal
worker counts, ``serving_socket`` checks the socket transport's
capacity ratio over the process transport at H=2 plus the HTTP front
door's modeled-p99 SLO and host-kill requeue completeness, and
``serving_scenarios`` checks per-regime p99 latency and
cost-per-request ceilings of the MODELED accounting under provider
outage / price-war schedules (all machine-speed-invariant).
"""
from __future__ import annotations

import contextlib
import json
import os
import subprocess
import sys
import tempfile
from dataclasses import dataclass
from typing import List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(REPO, "benchmarks", "results")


@dataclass
class Gate:
    path: str               # dotted path into the result JSON
    direction: str = "higher"   # "higher" or "lower" is better

    def lookup(self, obj):
        for part in self.path.split("."):
            obj = obj[part]
        return float(obj)


# Warm-path metrics gated per benchmark.  All are higher-is-better
# speedup ratios of an optimized path over a reference path in the same
# run (machine-speed invariant).  BENCH_ENV pins each fresh run to the
# same problem size its committed baseline was recorded at (overridable
# from the caller's environment).
GATES = {
    "subset_cache": [Gate("speedup_warm"), Gate("speedup_cold")],
    # full-lattice pass vs the per-bitmask loop, both cold, same run:
    # the ratio cancels machine speed.  N=5 is reported but not gated —
    # at 31 subsets the vectorized pass has little to amortize and its
    # ratio is the noisiest of the three
    "lattice": [Gate("speedup_n7"), Gate("speedup_n10")],
    "serving": [Gate("speedup_async_vs_handle"),
                Gate("speedup_many_vs_handle")],
    "train_driver": [Gate("offpolicy.speedup"), Gate("ppo.speedup"),
                     Gate("offpolicy.speedup_device_vs_host")],
    # machine-invariant roofline gates: FLOPs parity of the scanned
    # update block vs K eager steps and the batched-IoU arithmetic
    # intensity are HLO-derived (deterministic per XLA version); the two
    # speedups are same-run ratios, which cancel absolute machine speed
    "roofline": [Gate("fused_update.flops_parity"),
                 Gate("fused_update.speedup_fused_vs_eager"),
                 Gate("iou_batch.hlo_intensity"),
                 Gate("replay_chain.speedup_device_vs_host")],
    # scenario gates are quality ratios, not timings: post-switch
    # recovery vs the per-segment oracle and the warm-path cache hit
    # rate the stream saw — both machine-speed invariant
    "scenarios": [Gate("summary.min_recovery"),
                  Gate("summary.mean_cache_hit_rate")],
    # process-vs-thread shard capacity ratios at equal W (same machine,
    # same run, interleaved rounds: absolute speed cancels).  w4 is the
    # acceptance headline.  w1 is reported but NOT gated: one worker has
    # no parallelism to win, so its ratio is noise around 1.0 by design
    "serving_mp": [Gate("speedup_process_vs_thread_w4"),
                   Gate("speedup_process_vs_thread_w2")],
    # socket-vs-process shard capacity ratio at H=2 (same machine, same
    # run, interleaved rounds — the TCP plane's framing overhead check;
    # h1 is reported but not gated, one host has nothing to amortize),
    # the HTTP front door's MODELED p99 (paper latency model + pinned
    # seeds: transport may slow a run, it must never change the model's
    # answer), and the host-kill requeue completing every request
    "serving_socket": [Gate("speedup_socket_vs_process_h2"),
                       Gate("http.modeled_p99_ms", "lower"),
                       Gate("host_kill.completed_frac")],
    # SLO ceilings under provider dynamics: worst per-regime p99 of the
    # MODELED request latency and mean cost per request (both follow
    # from the paper's latency/fee model + pinned seeds, so they are
    # machine-speed-invariant; "lower" direction makes the committed
    # baseline a ceiling that REPRO_BENCH_TOLERANCE widens)
    "serving_scenarios": [
        Gate("provider_outage.worst_p99_ms", "lower"),
        Gate("provider_outage.cost_per_request", "lower"),
        Gate("price_war.worst_p99_ms", "lower"),
        Gate("price_war.cost_per_request", "lower")],
    # cost-accuracy frontier dominance invariants: 1.0/0.0 flags (some
    # RL point matches the cheapest single's cost / the all-providers
    # accuracy within the recorded eps margins; hybrid earns >= cascade
    # reward at every shared beta) plus the paper operating point's fee
    # saving at matched accuracy.  Every input is seeded/modeled — no
    # wall clock anywhere — so these are machine-invariant quantities
    "frontier": [Gate("invariants.rl_dominates_cheapest"),
                 Gate("invariants.rl_dominates_all_providers"),
                 Gate("invariants.hybrid_ge_cascade"),
                 Gate("paper_point.cost_saving_frac")],
    # observability overhead: instrumented-vs-bare serving throughput in
    # the same run, interleaved rounds (absolute speed cancels).  The
    # committed ratio must stay ~1.0 — obs on the hot path is required
    # to be within noise of obs off
    "obs_overhead": [Gate("throughput_ratio")],
}

BENCH_ENV = {
    "subset_cache": {"REPRO_BENCH_IMAGES": "50"},
    "lattice": {"REPRO_BENCH_IMAGES": "12",
                "REPRO_BENCH_ROUNDS": "3"},
    "serving": {"REPRO_BENCH_IMAGES": "50"},
    "train_driver": {"REPRO_BENCH_IMAGES": "120"},
    "roofline": {"REPRO_BENCH_ROUNDS": "5"},
    "scenarios": {"REPRO_BENCH_IMAGES": "120",
                  "REPRO_BENCH_HORIZON": "1600"},
    "serving_mp": {"REPRO_BENCH_IMAGES": "240",
                   "REPRO_BENCH_MAX_BATCH": "16",
                   "REPRO_BENCH_ROUNDS": "5"},
    "serving_socket": {"REPRO_BENCH_IMAGES": "240",
                       "REPRO_BENCH_MAX_BATCH": "16",
                       "REPRO_BENCH_ROUNDS": "3"},
    "serving_scenarios": {"REPRO_BENCH_IMAGES": "120",
                          "REPRO_BENCH_REQUESTS": "600",
                          "REPRO_BENCH_MAX_BATCH": "16",
                          "REPRO_BENCH_WORKERS": "4"},
    "frontier": {"REPRO_BENCH_IMAGES": "96",
                 "REPRO_BENCH_FRONTIER_HORIZON": "480"},
    "obs_overhead": {"REPRO_BENCH_IMAGES": "120",
                     "REPRO_BENCH_REQUESTS": "480",
                     "REPRO_BENCH_MAX_BATCH": "16",
                     "REPRO_BENCH_ROUNDS": "5"},
}

DEFAULT = ["subset_cache", "serving"]


def run_fresh(name: str, results_dir: str) -> dict:
    env = dict(os.environ)
    env["REPRO_RESULTS_DIR"] = results_dir
    for k, v in BENCH_ENV.get(name, {}).items():
        env.setdefault(k, v)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"), name],
        check=True, env=env, cwd=REPO)
    with open(os.path.join(results_dir, f"{name}.json")) as f:
        return json.load(f)


def check(name: str, fresh: dict, baseline: dict,
          tolerance: float) -> List[str]:
    """Compare per-gate values (dicts keyed by gate path) and report."""
    failures = []
    for gate in GATES[name]:
        base, new = baseline[gate.path], fresh[gate.path]
        if gate.direction == "higher":
            regression = (base - new) / base if base else 0.0
        else:
            regression = (new - base) / base if base else 0.0
        status = "FAIL" if regression > tolerance else "ok"
        print(f"  [{status}] {name}.{gate.path}: baseline={base:g} "
              f"fresh={new:g} regression={100 * regression:+.1f}% "
              f"(tolerance {100 * tolerance:.0f}%)")
        if regression > tolerance:
            failures.append(f"{name}.{gate.path}")
    return failures


def main(argv: List[str]) -> int:
    names = [a for a in argv if not a.startswith("-")] or list(DEFAULT)
    tolerance = float(os.environ.get("REPRO_BENCH_TOLERANCE", "0.30"))
    unknown = [n for n in names if n not in GATES]
    if unknown:
        print(f"no gates defined for: {', '.join(unknown)} "
              f"(gated: {', '.join(GATES)})")
        return 2
    retries = int(os.environ.get("REPRO_BENCH_RETRIES", "1"))
    failures: List[str] = []
    # REPRO_BENCH_SCRATCH pins the fresh-results dir to a known path so
    # CI can upload the measured JSONs as workflow artifacts; unset, a
    # throwaway tempdir keeps local runs tidy
    with contextlib.ExitStack() as stack:
        scratch = os.environ.get("REPRO_BENCH_SCRATCH")
        if scratch:
            os.makedirs(scratch, exist_ok=True)
        else:
            scratch = stack.enter_context(
                tempfile.TemporaryDirectory(prefix="repro-bench-"))
        for name in names:
            base_path = os.path.join(BASELINE_DIR, f"{name}.json")
            if not os.path.exists(base_path):
                print(f"[check_bench] no committed baseline for '{name}' "
                      f"({base_path}); run the benchmark and commit its "
                      f"results/ JSON first")
                return 2
            with open(base_path) as f:
                baseline = json.load(f)
            base_vals = {g.path: g.lookup(baseline) for g in GATES[name]}
            best: dict = {}
            for attempt in range(1 + retries):
                print(f"[check_bench] {name}: running fresh benchmark "
                      f"(attempt {attempt + 1}/{1 + retries}) ...")
                fresh = run_fresh(name, scratch)
                # keep the best value seen per metric: a transient load
                # spike on a shared machine compresses the speedup
                # ratios, it never inflates them
                for gate in GATES[name]:
                    v = gate.lookup(fresh)
                    if gate.path not in best or (
                            (v > best[gate.path])
                            == (gate.direction == "higher")):
                        best[gate.path] = v
                bench_fails = check(name, best, base_vals, tolerance)
                if not bench_fails:
                    break
            failures += bench_fails
    if failures:
        print(f"[check_bench] FAILED: {len(failures)} metric(s) regressed "
              f"beyond {100 * tolerance:.0f}%: {', '.join(failures)}")
        print("[check_bench] on a noisy runner, retry or raise "
              "REPRO_BENCH_TOLERANCE (e.g. REPRO_BENCH_TOLERANCE=0.6)")
        return 1
    print("[check_bench] OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
