#!/usr/bin/env bash
# CI entry point.
#
#   tools/ci.sh            tier-1 lane: import hygiene, fast tests
#                          (-m "not slow"), docs check, subset-cache
#                          smoke benchmark
#   tools/ci.sh --tests    tier-1 tests only        (matrix job: tests)
#   tools/ci.sh --hygiene  hygiene + smoke bench    (matrix job: hygiene)
#   tools/ci.sh --docs     docs lane: intra-repo link check (anchors
#                          included) and every committed
#                          benchmarks/results/*.json baseline must be
#                          referenced from README.md or docs/
#                          (matrix job: docs)
#   tools/ci.sh --full     everything: slow driver/serving tests + the
#                          benchmark regression gates (tools/check_bench.py
#                          compares fresh subset_cache/lattice/serving/
#                          train_driver/scenarios/serving_mp/
#                          serving_socket/serving_scenarios/roofline/
#                          frontier/obs_overhead numbers
#                          against the committed benchmarks/results/*.json
#                          baselines; REPRO_BENCH_TOLERANCE overrides the
#                          30% gate on noisy runners)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FULL=0 TESTS=1 HYGIENE=1 DOCS=1
case "${1:-}" in
    --full)    FULL=1 ;;
    --tests)   HYGIENE=0 DOCS=0 ;;
    --hygiene) TESTS=0 DOCS=0 ;;
    --docs)    TESTS=0 HYGIENE=0 ;;
    "") ;;
    *) echo "usage: tools/ci.sh [--full|--tests|--hygiene|--docs]" >&2
       exit 2 ;;
esac

if [[ "$HYGIENE" == 1 ]]; then
echo "== optional-dependency import hygiene =="
# hypothesis (property tests) and jax (accelerator extras) are optional
# on minimal containers: any test importing them without a preceding
# pytest.importorskip guard breaks collection there.
python - <<'PY'
import pathlib
import re
import sys

bad = []
for mod in ("hypothesis", "jax"):
    for path in pathlib.Path("tests").glob("*.py"):
        src = path.read_text()
        imp = re.search(rf"^\s*(?:from|import)\s+{mod}\b", src, re.M)
        if imp is None:
            continue
        # the guard must RUN BEFORE the first import executes
        skip = re.search(rf"importorskip\(\s*['\"]{mod}['\"]\s*\)", src)
        if skip is None or skip.start() > imp.start():
            bad.append(f"{path} ({mod})")


def guarded_suite(pattern, why, *, require_slow_when=None):
    """Suites that import repro.* (pulling jax transitively) and may run
    drivers or spawn worker processes: every file must guard jax
    explicitly, and files matching ``require_slow_when`` must mark
    themselves slow so the tier-1 lane stays fast.  Offenders are listed
    by name so the failure is actionable, and a missing suite is itself
    an offense (the gate must not pass vacuously)."""
    files = sorted(pathlib.Path("tests").glob(pattern))
    if not files:
        bad.append(f"tests/{pattern} (missing: {why})")
    for path in files:
        src = path.read_text()
        if 'importorskip("jax")' not in src and \
                "importorskip('jax')" not in src:
            bad.append(f"{path} (no jax importorskip)")
        if require_slow_when is None or require_slow_when(src):
            if "pytest.mark.slow" not in src:
                bad.append(f"{path} (no slow marker: {why})")


guarded_suite("test_scenarios*.py", "scenario suite",
              require_slow_when=lambda src: "run_online" in src)
# the lattice parity suite property-tests all 2^N - 1 subsets per draw
# and spins up process shards for the wire-contract case: jax must be
# guarded and the process-backend cases slow-marked
guarded_suite("test_lattice_eval*.py", "lattice parity suite")
# multi-process serving suites spawn worker processes (seconds each on
# the spawn context): slow-marked wholesale, nightly --full runs them
guarded_suite("test_serving_mp*.py", "process-shard serving suite")
# socket suites additionally spawn TCP shard-host processes and an HTTP
# front door: slow-marked wholesale like the mp suites
guarded_suite("test_serving_socket*.py", "socket-shard serving suite")
guarded_suite("test_serving_scenarios*.py", "scenario serving suite")
# device-resident training: the parity suite trains full drivers for
# the bit-identical device-vs-host assertions (slow when it does), and
# the roofline suite compiles/times jitted programs
guarded_suite("test_device_replay*.py", "device replay parity suite",
              require_slow_when=lambda src: "run_off_policy" in src)
guarded_suite("test_roofline*.py", "roofline measurement suite")
# selector policies (cascade/MCT/hybrid) spin serving planes and score
# scenario segments; anything training RL arms online must be slow
guarded_suite("test_selection*.py", "selector policy suite",
              require_slow_when=lambda src: "run_online" in src)
# observability: the unit suite stays fast; anything driving online
# training or the process-shard backend must be slow-marked
guarded_suite("test_obs*.py", "observability suite",
              require_slow_when=lambda src: "run_online" in src
              or "shard_backend" in src)
if bad:
    sys.exit("optional dependency imported without a preceding "
             "pytest.importorskip guard (or serving/scenario test "
             "hygiene violation): " + ", ".join(bad))
print("ok")
PY
fi

if [[ "$DOCS" == 1 || "$FULL" == 1 ]]; then
echo "== docs: intra-repo links + baseline coverage =="
python - <<'PY'
import functools
import pathlib
import re
import sys

root = pathlib.Path(".")
pages = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
bad = []


def slug(heading):
    """GitHub's heading -> anchor rule: lowercase, drop punctuation,
    spaces to hyphens."""
    heading = re.sub(r"[^\w\- ]", "", heading.strip().lower())
    return heading.replace(" ", "-")


@functools.lru_cache(maxsize=None)
def anchors_of(path):
    text = pathlib.Path(path).read_text()
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return {slug(m.group(1))
            for m in re.finditer(r"^#{1,6}\s+(.*)$", text, re.M)}


LINK = re.compile(r"\]\(([^)\s]+)\)")
for page in pages:
    text = re.sub(r"```.*?```", "", page.read_text(), flags=re.S)
    for target in LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = page if not path_part else \
            (page.parent / path_part).resolve()
        if not dest.exists():
            bad.append(f"{page}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md" and \
                anchor not in anchors_of(str(dest)):
            bad.append(f"{page}: broken anchor -> {target}")

# every committed baseline must be documented somewhere a reader looks
corpus = "\n".join(p.read_text() for p in pages)
for res in sorted((root / "benchmarks" / "results").glob("*.json")):
    if res.stem not in corpus:
        bad.append(f"benchmarks/results/{res.name}: baseline not "
                   "referenced in README.md or docs/")

if bad:
    sys.exit("docs check failed:\n  " + "\n  ".join(bad))
print(f"ok ({len(pages)} pages)")
PY
fi

if [[ "$FULL" == 1 ]]; then
    echo "== tests (full, slow included) =="
    python -m pytest -x -q
elif [[ "$TESTS" == 1 ]]; then
    echo "== tier-1 tests =="
    python -m pytest -x -q -m "not slow"
fi

if [[ "$FULL" == 1 ]]; then
    echo "== benchmark regression gates (fresh vs committed baselines) =="
    python tools/check_bench.py subset_cache lattice serving \
        train_driver scenarios serving_mp serving_socket \
        serving_scenarios roofline frontier obs_overhead
elif [[ "$HYGIENE" == 1 ]]; then
    echo "== subset-cache smoke benchmark (50 images) =="
    # scratch results dir: the committed baselines under benchmarks/
    # results/ are the check_bench reference and must not be clobbered
    REPRO_RESULTS_DIR="$(mktemp -d)" REPRO_BENCH_IMAGES=50 \
        python benchmarks/run.py subset_cache
fi

echo "CI OK"
