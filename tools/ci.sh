#!/usr/bin/env bash
# CI entry point.
#
#   tools/ci.sh          tier-1 lane: import hygiene, fast tests
#                        (-m "not slow"), subset-cache smoke benchmark
#   tools/ci.sh --full   everything: slow driver tests + the benchmark
#                        regression gates (tools/check_bench.py compares
#                        fresh subset_cache/serving/train_driver/scenarios
#                        numbers against the committed benchmarks/
#                        results/*.json baselines; REPRO_BENCH_TOLERANCE
#                        overrides the 30% gate on noisy runners)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FULL=0
if [[ "${1:-}" == "--full" ]]; then
    FULL=1
fi

echo "== optional-dependency import hygiene =="
# hypothesis (property tests) and jax (accelerator extras) are optional
# on minimal containers: any test importing them without a preceding
# pytest.importorskip guard breaks collection there.
python - <<'PY'
import pathlib
import re
import sys

bad = []
for mod in ("hypothesis", "jax"):
    for path in pathlib.Path("tests").glob("*.py"):
        src = path.read_text()
        imp = re.search(rf"^\s*(?:from|import)\s+{mod}\b", src, re.M)
        if imp is None:
            continue
        # the guard must RUN BEFORE the first import executes
        skip = re.search(rf"importorskip\(\s*['\"]{mod}['\"]\s*\)", src)
        if skip is None or skip.start() > imp.start():
            bad.append(f"{path} ({mod})")
# scenario tests import repro.* (which pulls jax transitively) and run
# training drivers: each file must guard jax explicitly and mark its
# driver tests slow so the tier-1 lane stays fast
scen = sorted(pathlib.Path("tests").glob("test_scenarios*.py"))
if not scen:
    bad.append("tests/test_scenarios*.py (missing)")
for path in scen:
    src = path.read_text()
    if 'importorskip("jax")' not in src and \
            "importorskip('jax')" not in src:
        bad.append(f"{path} (no jax importorskip)")
    if "run_online" in src and "pytest.mark.slow" not in src:
        bad.append(f"{path} (online-driver test without a slow marker)")
if bad:
    sys.exit("optional dependency imported without a preceding "
             "pytest.importorskip guard (or scenario-test hygiene "
             "violation): " + ", ".join(bad))
print("ok")
PY

if [[ "$FULL" == 1 ]]; then
    echo "== tests (full, slow included) =="
    python -m pytest -x -q
else
    echo "== tier-1 tests =="
    python -m pytest -x -q -m "not slow"
fi

if [[ "$FULL" == 1 ]]; then
    echo "== benchmark regression gates (fresh vs committed baselines) =="
    python tools/check_bench.py subset_cache serving train_driver scenarios
else
    echo "== subset-cache smoke benchmark (50 images) =="
    # scratch results dir: the committed baselines under benchmarks/
    # results/ are the check_bench reference and must not be clobbered
    REPRO_RESULTS_DIR="$(mktemp -d)" REPRO_BENCH_IMAGES=50 \
        python benchmarks/run.py subset_cache
fi

echo "CI OK"
