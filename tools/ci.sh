#!/usr/bin/env bash
# CI entry point.
#
#   tools/ci.sh          tier-1 lane: import hygiene, fast tests
#                        (-m "not slow"), subset-cache smoke benchmark
#   tools/ci.sh --full   everything: slow driver tests + the batched-vs-
#                        sequential train-driver benchmark
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FULL=0
if [[ "${1:-}" == "--full" ]]; then
    FULL=1
fi

echo "== hypothesis import hygiene =="
# hypothesis is an optional dependency: any test importing it without the
# importorskip guard breaks collection on minimal containers.
python - <<'PY'
import pathlib
import re
import sys

bad = []
for path in pathlib.Path("tests").glob("*.py"):
    src = path.read_text()
    imp = re.search(r"^\s*(?:from|import)\s+hypothesis\b", src, re.M)
    if imp is None:
        continue
    # the guard must RUN BEFORE the first hypothesis import executes
    skip = re.search(r"importorskip\(\s*['\"]hypothesis['\"]\s*\)", src)
    if skip is None or skip.start() > imp.start():
        bad.append(str(path))
if bad:
    sys.exit("hypothesis imported without a preceding "
             "pytest.importorskip guard: " + ", ".join(bad))
print("ok")
PY

if [[ "$FULL" == 1 ]]; then
    echo "== tests (full, slow included) =="
    python -m pytest -x -q
else
    echo "== tier-1 tests =="
    python -m pytest -x -q -m "not slow"
fi

echo "== subset-cache smoke benchmark (50 images) =="
REPRO_BENCH_IMAGES=50 python benchmarks/run.py subset_cache

if [[ "$FULL" == 1 ]]; then
    echo "== train-driver benchmark (batched vs sequential) =="
    python benchmarks/run.py train_driver
fi

echo "CI OK"
