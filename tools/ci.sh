#!/usr/bin/env bash
# CI entry point: tier-1 tests + a smoke benchmark of the subset-evaluation
# core (the hot path this repo is built around).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== subset-cache smoke benchmark (50 images) =="
REPRO_BENCH_IMAGES=50 python benchmarks/run.py subset_cache

echo "CI OK"
